"""Bench-smoke perf gate — the headline numbers can't silently regress.

Reads the artifacts ``benchmarks.run --smoke`` just wrote and asserts the
pipelined-staging headline (ISSUE 6):

* ``pipelined_speedup >= 1.3`` at the paper-crossover regime (heSoC n=128
  float64, where T_copy ~ T_compute — the overlap win ROADMAP item 2 claims);
* tpu-v5e large-n steady-state ``copy_fraction < 0.6`` (serial staging
  spends 0.60 of offload time copying there; the pipeline must hide it);
* tpu-v5e n=2048 cold ``offload_s`` within 15% of ``max(copy, compute)``
  (the acceptance criterion: a shingle, not a sum);

and the streaming-serve headline (ISSUE 8):

* an ``offered_load_sweep`` section with >= 3 load points, each carrying
  sustained QPS, TTFT/per-token p50/p95/p99 and the admission reject rate;
* ``max_qps_at_slo > 0`` — the server sustains at least one load point
  inside the p99 TTFT/per-token SLO — and the recorded trace ``seed`` is
  present (the sweep is replayable);
* continuous batching beats the lock-step baseline by >= 1.3x sustained
  QPS on the same bursty trace at the knee, and the knee's sustained QPS
  is >= the best lock-step point;
* ``BENCH_trajectory.jsonl`` has no duplicate (commit, headline-hash)
  lines and its latest line carries the serve headline keys;

and the dynamic expert-placement headline (ISSUE 10):

* an ``expert_placement`` section with >= 3 Zipf skew points, each
  carrying its recorded seed, on >= 4 lanes;
* dynamic placement beats static contiguous-block homes by >= 1.2x
  modeled makespan at Zipf s=1.2 (migration/replication d2d charged on
  the DMA stream clocks);
* token conservation at every point: routed = processed + dropped for
  both the static and dynamic runs — zero unaccounted dropped tokens;

and the observability contract (ISSUE 9):

* ``trace_smoke.json`` (from ``make trace-smoke``) loads, is non-empty,
  and its embedded ``repro_obs`` coverage says every LaunchTicket the
  smoke workloads issued has a matching span — no silent blind spots in
  the instrumentation;
* ``BENCH_offload.json`` carries a non-empty ``metrics`` snapshot.

Run: PYTHONPATH=src:. python tools/check_bench_gate.py [--offload PATH]
     [--trajectory PATH] [--trace PATH]

Exit code 0 = gate holds; 1 = regression (each failure printed).
"""

from __future__ import annotations

import argparse
import json
import sys


def check_offload(summary: dict) -> list:
    failures = []
    pipe = summary.get("pipelined_staging")
    if not pipe:
        return ["BENCH_offload.json has no pipelined_staging section"]

    crossover = pipe["paper_crossover"]
    if crossover["pipelined_speedup"] < 1.3:
        failures.append(
            "paper-crossover pipelined_speedup "
            f"{crossover['pipelined_speedup']:.3f} < 1.3"
        )

    steady = pipe["tpu_large_n_steady"]
    if steady["pipelined_copy_fraction"] >= 0.6:
        failures.append(
            "tpu-v5e large-n steady pipelined copy_fraction "
            f"{steady['pipelined_copy_fraction']:.3f} >= 0.6"
        )

    n2048 = pipe["tpu_n2048"]
    if n2048["pipelined_vs_max"] > 1.15:
        failures.append(
            "tpu-v5e n=2048 pipelined offload_s is "
            f"{n2048['pipelined_vs_max']:.3f}x max(copy, compute) > 1.15x"
        )
    return failures


_POINT_KEYS = (
    "sustained_qps", "reject_rate",
    "ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
    "per_token_p50_ms", "per_token_p95_ms", "per_token_p99_ms",
)


def check_serve(summary: dict) -> list:
    failures = []
    sweep = summary.get("offered_load_sweep")
    if not sweep:
        return ["BENCH_offload.json has no offered_load_sweep section"]
    points = sweep.get("points", [])
    if len(points) < 3:
        failures.append(
            f"offered_load_sweep has {len(points)} load points < 3"
        )
    for i, p in enumerate(points):
        missing = [k for k in _POINT_KEYS if k not in p]
        if missing:
            failures.append(
                f"offered_load_sweep point {i} is missing {missing}"
            )
    if "seed" not in sweep:
        failures.append(
            "offered_load_sweep records no seed — the sweep is not replayable"
        )
    max_qps = sweep.get("max_qps_at_slo", 0.0)
    if not max_qps or max_qps <= 0:
        failures.append(
            "max_qps_at_slo headline missing or zero — no load point met "
            "the p99 TTFT/per-token SLO"
        )
    vs = sweep.get("continuous_vs_lockstep", {})
    speedup = vs.get("speedup", 0.0)
    if speedup < 1.3:
        failures.append(
            "continuous batching beats lock-step by only "
            f"{speedup:.3f}x sustained QPS (< 1.3x) on the same bursty trace"
        )
    lock_best = max(
        (p.get("sustained_qps", 0.0) for p in sweep.get("lockstep_points", [])),
        default=0.0,
    )
    if vs.get("continuous_qps", 0.0) < lock_best:
        failures.append(
            f"knee sustained QPS {vs.get('continuous_qps', 0.0):.1f} < best "
            f"lock-step point {lock_best:.1f}"
        )
    return failures


def check_expert_placement(summary: dict) -> list:
    failures = []
    sec = summary.get("expert_placement")
    if not sec:
        return ["BENCH_offload.json has no expert_placement section"]
    points = sec.get("points", [])
    if len(points) < 3:
        failures.append(
            f"expert_placement has {len(points)} skew points < 3"
        )
    if sec.get("num_lanes", 0) < 4:
        failures.append(
            f"expert_placement ran on {sec.get('num_lanes', 0)} lanes < 4"
        )
    gated = None
    for i, p in enumerate(points):
        if "seed" not in p:
            failures.append(
                f"expert_placement point {i} records no seed — not replayable"
            )
        for side in ("static", "dynamic"):
            un = p.get(side, {}).get("tokens_unaccounted")
            if un is None or un != 0:
                failures.append(
                    f"expert_placement point {i} ({side}, "
                    f"s={p.get('zipf_s')}): {un} unaccounted dropped "
                    "tokens — routed != processed + dropped"
                )
        if abs(p.get("zipf_s", 0.0) - 1.2) < 1e-9:
            gated = p
    if gated is None:
        failures.append(
            "expert_placement has no Zipf s=1.2 point — the gated skew "
            "regime was not measured"
        )
    elif gated.get("speedup", 0.0) < 1.2:
        failures.append(
            "dynamic placement beats static by only "
            f"{gated.get('speedup', 0.0):.3f}x modeled makespan at Zipf "
            "s=1.2 (< 1.2x)"
        )
    return failures


def check_trajectory(path: str) -> list:
    # Mirror benchmarks.run's dedupe key so the two stay in lockstep.
    from benchmarks.run import _headline_hash

    seen = set()
    failures = []
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path} is empty — bench-smoke did not record a headline"]
    for i, line in enumerate(lines, 1):
        try:
            e = json.loads(line)
        except ValueError:
            failures.append(f"{path}:{i}: not valid JSON")
            continue
        key = (e.get("commit", ""), _headline_hash(e.get("headline", {})))
        if key in seen:
            failures.append(
                f"{path}:{i}: duplicate headline for commit {key[0]!r}"
            )
        seen.add(key)
    last = json.loads(lines[-1])
    for key in ("pipelined_speedup", "max_qps_at_slo",
                "stream_vs_lockstep_qps", "expert_placement_speedup"):
        if key not in last.get("headline", {}):
            failures.append(f"{path}: latest headline is missing {key!r}")
    return failures


def check_obs(summary: dict, trace_path: str) -> list:
    failures = []
    try:
        with open(trace_path) as f:
            trace = json.load(f)
    except (OSError, ValueError) as e:
        return [f"cannot load {trace_path}: {e} — did `make trace-smoke` run?"]
    if not trace.get("traceEvents"):
        failures.append(f"{trace_path} has no traceEvents")
    obs = trace.get("repro_obs", {})
    cov = obs.get("coverage", {})
    if cov.get("tickets", 0) <= 0:
        failures.append(
            f"{trace_path} covers zero LaunchTickets — the smoke workloads "
            "issued nothing (or coverage metadata is missing)"
        )
    if cov.get("uncovered_tickets", 1) != 0:
        failures.append(
            f"{trace_path}: {cov.get('uncovered_tickets')} ticket(s) have no "
            "matching span — instrumentation has a blind spot"
        )
    if not summary.get("metrics"):
        failures.append(
            "BENCH_offload.json has no metrics snapshot — the registry "
            "rollup is not reaching the bench artifacts"
        )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--offload", default="BENCH_offload.json")
    ap.add_argument("--trajectory", default="BENCH_trajectory.jsonl")
    ap.add_argument("--trace", default="trace_smoke.json")
    args = ap.parse_args()

    try:
        with open(args.offload) as f:
            summary = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot load {args.offload}: {e}")
        return 1

    failures = (
        check_offload(summary)
        + check_serve(summary)
        + check_expert_placement(summary)
        + check_trajectory(args.trajectory)
        + check_obs(summary, args.trace)
    )
    if failures:
        print("bench gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1

    pipe = summary["pipelined_staging"]
    sweep = summary["offered_load_sweep"]
    print(
        "bench gate ok: pipelined_speedup="
        f"{pipe['paper_crossover']['pipelined_speedup']:.2f}x (>=1.3), "
        "tpu steady copy_fraction="
        f"{pipe['tpu_large_n_steady']['pipelined_copy_fraction']:.2f} (<0.6), "
        "n=2048 vs max(copy,compute)="
        f"{pipe['tpu_n2048']['pipelined_vs_max']:.3f}x (<=1.15), "
        f"max_qps_at_slo={sweep['max_qps_at_slo']:.0f} "
        f"({len(sweep['points'])} load points, continuous vs lockstep "
        f"{sweep['continuous_vs_lockstep']['speedup']:.2f}x >=1.3), "
        "expert placement dynamic vs static="
        f"{summary['expert_placement']['expert_placement_speedup']:.2f}x "
        f"@ s=1.2 (>=1.2, {len(summary['expert_placement']['points'])} skew "
        "points, tokens conserved), "
        "trajectory deduped, trace covered + metrics snapshot present"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
