"""Import-time gate for the lazy frontend (wired into ``make collect``).

``repro.hnp`` is the first thing a user imports, and its whole point is
transparency — it must not drag jax / the offload engine in at import.  The
frontend modules are import-light by contract (stdlib + numpy only at module
scope; everything heavy loads lazily at first use).  This script enforces
the contract: each ``repro.frontend`` module (and ``repro.hnp``) must import
in under ``BUDGET_S`` seconds in a *cold* interpreter.  A regression here
almost always means someone added a module-scope ``import jax`` (or pulled
in ``repro.core``), which costs seconds, not milliseconds.

Run: PYTHONPATH=src python tools/check_import_time.py
"""

from __future__ import annotations

import os
import subprocess
import sys

BUDGET_S = 1.0

MODULES = (
    "repro.frontend",
    "repro.frontend.lazy",
    "repro.frontend.schedule",
    "repro.frontend.api",
    "repro.hnp",
    # the analysis passes are import-light by the same contract: lint and
    # verification must be runnable (and fast) without dragging in jax
    "repro.analysis",
    "repro.analysis.base",
    "repro.analysis.graph",
    "repro.analysis.races",
    "repro.analysis.lint",
    # the observability layer is imported from the core hot seams and the
    # frontend; it must stay stdlib-only at module scope
    "repro.obs",
    "repro.obs.spans",
    "repro.obs.metrics",
    "repro.obs.flight",
    "repro.obs.trace_export",
)

_PROBE = r"""
import sys, time
mod = sys.argv[1]
t0 = time.perf_counter()
__import__(mod)
elapsed = time.perf_counter() - t0
heavy = [m for m in ("jax", "jaxlib") if m in sys.modules]
print(f"{elapsed:.3f} {','.join(heavy) or '-'}")
"""


def main() -> int:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    failed = False
    for mod in MODULES:
        proc = subprocess.run(
            [sys.executable, "-c", _PROBE, mod],
            capture_output=True, text=True, env=env, timeout=60,
        )
        if proc.returncode != 0:
            print(f"FAIL {mod}: import error\n{proc.stderr}", file=sys.stderr)
            failed = True
            continue
        elapsed_s, heavy = proc.stdout.split()
        elapsed = float(elapsed_s)
        status = "ok" if elapsed <= BUDGET_S else "TOO SLOW"
        print(f"{status:8s} {mod:28s} {elapsed:.3f}s (budget {BUDGET_S:.1f}s)")
        if elapsed > BUDGET_S:
            failed = True
        if heavy != "-":
            print(
                f"FAIL {mod}: module-scope import pulled in {heavy} — "
                "the frontend must load jax lazily",
                file=sys.stderr,
            )
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
