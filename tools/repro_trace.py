"""Capture a Perfetto-loadable trace of the smoke workloads (``make trace``).

Runs up to three modeled workloads under one span tracer each — an eager
GEMM chain, an ``hnp`` graph forward (waves, fusion, prefetch, d2d), and
a continuous-batching streaming burst — and writes the combined Chrome
trace-event JSON.  Load the file at https://ui.perfetto.dev (or
``chrome://tracing``): each workload is one process group; per device you
get a ``devN/dma`` and a ``devN/compute`` lane, flow arrows join d2d
migrations and slot refills, and counter tracks show in-flight depth,
resident bytes and decode slot occupancy.

The trace embeds a ``repro_obs`` section with ticket->span coverage
(every LaunchTicket the run issued must have a matching span — gated in
CI by ``tools/check_bench_gate.py --trace``) and the run's metrics
rollup.

Run:
    PYTHONPATH=src python tools/repro_trace.py --smoke [--summary]
    PYTHONPATH=src python tools/repro_trace.py --workload stream -o out.json
"""

from __future__ import annotations

import argparse
import collections
import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"),
)

from repro.obs import metrics, spans, trace_export  # noqa: E402

WORKLOADS = ("gemm", "graph", "stream")


def _ticket_key(t) -> tuple:
    return (t.device_id, t.kind, t.op, round(t.issue_s, 9),
            round(t.complete_s, 9))


def _engine_streams():
    from repro.core import engine

    return {d.device_id: list(d.inflight) for d in engine().devices}


def _workload_gemm() -> dict:
    """Eager BLAS chain on a 2-device cluster (dispatch + stream spans)."""
    import numpy as np

    from repro.core import blas, engine, offload_policy

    rng = np.random.default_rng(0)
    a = np.asarray(rng.normal(size=(512, 512)), np.float32)
    b = np.asarray(rng.normal(size=(512, 512)), np.float32)
    with offload_policy(mode="device", num_devices=2,
                        scheduler="round-robin", pipeline_staging=True):
        engine().reset()
        y = blas.gemm(a, b)
        for _ in range(3):
            y = blas.gemm(np.asarray(y), b)
        streams = _engine_streams()
        engine().sync()
    return streams


def _workload_graph() -> dict:
    """hnp graph forward: waves, fusion, batching, prefetch, d2d."""
    import numpy as np

    import repro.hnp as hnp
    from repro.core import engine, offload_policy

    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(256, 192)), np.float32)
    w1 = np.asarray(rng.normal(size=(192, 256)), np.float32)
    b1 = np.asarray(rng.normal(size=(256,)), np.float32)
    w2 = np.asarray(rng.normal(size=(256, 128)), np.float32)
    w3 = np.asarray(rng.normal(size=(256, 128)), np.float32)
    with offload_policy(mode="device", num_devices=4,
                        scheduler="cost-aware", prefetch_staging=True):
        engine().reset()
        with hnp.offload_region("trace-smoke"):
            h = hnp.tanh(hnp.linear(hnp.array(x), w1, b1))
            a = h @ w2
            b = h @ w3
            hnp.asnumpy(a + b)
            hnp.asnumpy(hnp.relu(h) @ w2)
        streams = _engine_streams()
        engine().sync()
    return streams


def _workload_stream() -> dict:
    """Continuous-batching burst: request lifecycles, AIMD, slot refills."""
    from repro.launch.streaming import bursty_trace, serve_stream

    trace = bursty_trace(80.0, 0.5, seed=0)
    report = serve_stream("yi-6b", trace)
    return report.ticket_log


_RUNNERS = {
    "gemm": _workload_gemm,
    "graph": _workload_graph,
    "stream": _workload_stream,
}


def capture(workloads) -> tuple:
    """Run the workloads, each under its own tracer; returns
    (tracers, coverage dict, metrics rollup)."""
    tracers = []
    tickets = collections.Counter()
    with metrics.collect() as reg:
        for name in workloads:
            with spans.span_trace(name) as tr:
                streams = _RUNNERS[name]()
            tracers.append(tr)
            for ts in streams.values():
                tickets.update(_ticket_key(t) for t in ts)
    span_keys = collections.Counter(
        (s.device_id, s.attrs["kind"], s.attrs["op"],
         round(s.attrs["issue_s"], 9), round(s.attrs["complete_s"], 9))
        for tr in tracers for s in tr.spans if s.attrs.get("ticket")
    )
    uncovered = tickets - span_keys
    coverage = {
        "tickets": sum(tickets.values()),
        "ticket_spans": sum(span_keys.values()),
        "uncovered_tickets": sum(uncovered.values()),
        "workloads": list(workloads),
    }
    return tracers, coverage, reg.rollup()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run all three smoke workloads (same as default)")
    ap.add_argument("--workload", choices=("all",) + WORKLOADS,
                    default="all", help="which workload to trace")
    ap.add_argument("-o", "--out", default="trace.json",
                    help="output path (default trace.json)")
    ap.add_argument("--summary", action="store_true",
                    help="print top-10 spans by self-time per lane")
    args = ap.parse_args(argv)

    workloads = WORKLOADS if (args.smoke or args.workload == "all") \
        else (args.workload,)
    tracers, coverage, rollup = capture(workloads)

    trace = trace_export.chrome_trace(
        tracers,
        meta={"repro_obs": {"coverage": coverage, "metrics": rollup}},
    )
    errors = trace_export.validate_chrome_trace(trace)
    if errors:
        for e in errors:
            print(f"repro-trace: INVALID: {e}", file=sys.stderr)
        return 1
    if coverage["uncovered_tickets"]:
        print(
            f"repro-trace: {coverage['uncovered_tickets']} tickets have no "
            "matching span", file=sys.stderr,
        )
        return 1

    trace_export.write_trace(args.out, trace)
    nspans = sum(len(tr.spans) for tr in tracers)
    print(
        f"repro-trace: {args.out} — {len(trace['traceEvents'])} events, "
        f"{nspans} spans over {len(tracers)} workload(s), "
        f"{coverage['tickets']} tickets all covered"
    )
    if args.summary:
        for tr in tracers:
            print(f"\n== {tr.name}: top spans by self-time ==")
            print(trace_export.summarize(tr.spans, top=10))
    return 0


if __name__ == "__main__":
    sys.exit(main())
