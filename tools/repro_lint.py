"""Unified repo lint driver (``make lint``).

Default mode runs the :mod:`repro.analysis.lint` rule engine over
``src/repro`` (plus the repo-level registry-closure rule) and prints one
``path:line: rule: message`` line per violation — exit 1 if any.

``--smoke-races`` instead exercises the *dynamic* passes end to end: it
runs a small ``hnp`` workload on a 4-device modeled cluster with pipelined
staging + cross-wave prefetch under ``validate=True`` (the graph verifier
checks every forced graph pre-dispatch), then feeds the resulting
``LaunchTicket`` event streams to the happens-before race detector, then
replays the continuous-batching streaming server over a seeded bursty
trace — its full ticket log through the same checker plus every
slot-refill edge through ``race/slot-refill-before-complete`` — and
finally replays a seeded Zipf-skewed expert-routing workload so every
dynamic-placement migration edge goes through
``race/expert-migrate-before-drain``.  A clean tree must produce zero
violations from all passes.

Run:
    PYTHONPATH=src python tools/repro_lint.py [paths...]
    PYTHONPATH=src python tools/repro_lint.py --smoke-races
"""

from __future__ import annotations

import argparse
import os
import pathlib
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"),
)

from repro.analysis.base import format_violations  # noqa: E402

FLIGHT_DUMP = "flight_dump.json"


def _dump_flight(violations) -> None:
    """A red dynamic-pass run ships its own repro trace: freeze the obs
    flight recorder's bounded ticket/span window next to the violations."""
    from repro.obs import flight

    path = flight.dump(FLIGHT_DUMP, violations)
    print(f"repro-lint: flight recorder window dumped to {path}",
          file=sys.stderr)


def run_rules(paths) -> int:
    from repro.analysis.lint import RULES, repo_root, run_lint

    root = repo_root()
    violations = run_lint(root, paths=[pathlib.Path(p) for p in paths] or None)
    if violations:
        print(format_violations(violations))
        print(f"repro-lint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    nfiles = sum(
        1 for p in (paths or [root / "src" / "repro"])
        for _ in pathlib.Path(p).rglob("*.py")
    )
    print(f"repro-lint: clean ({nfiles} files, {len(RULES)} rules + registry closure)")
    return 0


def run_smoke_races() -> int:
    import numpy as np

    import repro.hnp as hnp
    from repro.analysis.races import check_ticket_streams, ticket_streams
    from repro.core import engine, offload_policy

    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(256, 192)), np.float32)
    w1 = np.asarray(rng.normal(size=(192, 256)), np.float32)
    b1 = np.asarray(rng.normal(size=(256,)), np.float32)
    w2 = np.asarray(rng.normal(size=(256, 128)), np.float32)
    w3 = np.asarray(rng.normal(size=(256, 128)), np.float32)

    engine().reset()
    with offload_policy(mode="device", num_devices=4, scheduler="cost-aware",
                        prefetch_staging=True):
        # validate=True: pass 1 verifies each forced graph pre-dispatch
        with hnp.offload_region("lint-smoke", validate=True):
            h = hnp.tanh(hnp.linear(hnp.array(x), w1, b1))
            a = h @ w2                  # independent same-shape GEMMs: batch
            b = h @ w3
            hnp.asnumpy(a + b)
            hnp.asnumpy(hnp.relu(h) @ w2)   # second wave: prefetch + d2d
        streams = ticket_streams()
        violations = check_ticket_streams(streams)

    ntickets = sum(len(ts) for ts in streams.values())
    if violations:
        print(format_violations(violations))
        _dump_flight(violations)
        print(
            f"repro-lint --smoke-races: {len(violations)} violation(s) over "
            f"{ntickets} tickets",
            file=sys.stderr,
        )
        return 1
    kinds = sorted({t.kind for ts in streams.values() for t in ts})
    print(
        f"repro-lint --smoke-races: clean ({ntickets} tickets on "
        f"{len(streams)} devices, kinds: {'/'.join(kinds)}; graph verifier "
        "ran on every forced graph)"
    )
    return run_smoke_stream_races()


def run_smoke_stream_races() -> int:
    """Replay the continuous-batching engine and race-check its streams.

    Exercises the serving-specific invariants end to end: the full
    per-device ticket log (not the bounded in-flight window) goes through
    the happens-before checker, and every ``SlotRefill`` edge through the
    ``race/slot-refill-before-complete`` rule."""
    from repro.analysis.races import check_slot_refills, check_ticket_streams
    from repro.launch.streaming import bursty_trace, serve_stream

    trace = bursty_trace(120.0, 0.75, seed=0)
    report = serve_stream("yi-6b", trace)
    violations = check_ticket_streams(report.ticket_log)
    violations += check_slot_refills(report.slot_refills)
    ntickets = sum(len(ts) for ts in report.ticket_log.values())
    if violations:
        print(format_violations(violations))
        _dump_flight(violations)
        print(
            f"repro-lint --smoke-races: {len(violations)} violation(s) over "
            f"the streaming-serve workload ({ntickets} tickets)",
            file=sys.stderr,
        )
        return 1
    print(
        f"repro-lint --smoke-races: streaming serve clean ({ntickets} "
        f"tickets, {len(report.slot_refills)} slot-refill edges, "
        f"{report.completed}/{report.admitted} requests completed)"
    )
    return run_smoke_expert_races()


def run_smoke_expert_races() -> int:
    """Replay a Zipf-skewed expert-routing workload and race-check it.

    Drives the dynamic expert-placement policy over seeded skewed router
    traffic (migrations and replications must fire), then checks the
    per-lane ticket streams for happens-before and every migration edge
    for ``race/expert-migrate-before-drain`` — the d2d that moves an
    expert's weights may not issue while a source-lane launch still
    reading the handle is in flight."""
    from repro.analysis.races import (
        check_expert_migrations,
        check_ticket_streams,
    )
    from repro.core.placement import run_skewed_workload

    result = run_skewed_workload(zipf_s=1.2, seed=0, dynamic=True)
    violations = check_ticket_streams(result.ticket_streams)
    violations += check_expert_migrations(result.migration_edges)
    ntickets = sum(len(ts) for ts in result.ticket_streams.values())
    if violations:
        print(format_violations(violations))
        _dump_flight(violations)
        print(
            f"repro-lint --smoke-races: {len(violations)} violation(s) over "
            f"the skewed expert-placement workload ({ntickets} tickets)",
            file=sys.stderr,
        )
        return 1
    print(
        f"repro-lint --smoke-races: expert placement clean ({ntickets} "
        f"tickets, {len(result.migration_edges)} migration edges, "
        f"{result.migrations} migrations / {result.replications} "
        "replications under Zipf s=1.2)"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", help="files/dirs to lint (default: src/repro)")
    ap.add_argument(
        "--smoke-races", action="store_true",
        help="run the graph verifier + race detector over a smoke workload",
    )
    args = ap.parse_args(argv)
    if args.smoke_races:
        return run_smoke_races()
    return run_rules(args.paths)


if __name__ == "__main__":
    sys.exit(main())
